"""Paper Fig. 1: MMA invocations — 16×1 (SOTA) vs 8×1 (FlashSparse) vectors.

Exact structural counts from ME-BCRS (no execution).  The paper reports an
average 43% reduction at N=16; we reproduce the statistic on the scaled
suite and on every Table-4 preset.
"""

from __future__ import annotations

import numpy as np

from repro.core import from_coo, mma_count

from .common import geomean, suite, write_csv


def run(scale: float = 0.02, n_cols: int = 16, verbose: bool = True):
    rows = []
    for g in suite(scale):
        f8 = from_coo(g.rows, g.cols, g.vals, (g.num_nodes, g.num_nodes),
                      vector_size=8)
        f16 = from_coo(g.rows, g.cols, g.vals, (g.num_nodes, g.num_nodes),
                       vector_size=16)
        m8 = mma_count(f8, n_cols, "fp16")
        m16 = mma_count(f16, n_cols, "fp16")
        rows.append({
            "matrix": g.name, "nnz": g.num_edges,
            "mma_16x1": m16, "mma_8x1": m8,
            "reduction": 1.0 - m8 / max(m16, 1),
        })
        if verbose:
            print(f"  {g.name:16s} 16x1={m16:>10,} 8x1={m8:>10,} "
                  f"(-{rows[-1]['reduction']:.0%})")
    mean_red = float(np.mean([r["reduction"] for r in rows]))
    if verbose:
        print(f"  mean MMA reduction: {mean_red:.1%} "
              f"(paper Fig. 1: ≈43% at N=16)")
    write_csv("fig1_mma_counts.csv", rows)
    return {"mean_reduction": mean_red, "rows": rows}


if __name__ == "__main__":
    run()
