"""Fused sparse-attention benchmark: megakernel vs 3-dispatch staged.

Times the single-pass SDDMM→softmax→SpMM megakernel
(``attention/pallas_fused_attn``, one ``(H, W)`` grid launch, scores
resident in VMEM) against the staged pipeline
(``attention/pallas_staged``: SDDMM kernel → XLA sparse softmax → SpMM
kernel, the (NNZP, V) score tensor round-tripping HBM twice between the
three dispatches) per head count, and emits the machine-readable
``BENCH_attn.json`` perf record (median ms + modeled HBM bytes per
op/impl/matrix/H).  CI floor-checks the staged/fused HBM-reduction
geomean and that fused traffic is strictly below staged on **every**
shape — the megakernel's acceptance criterion.

  PYTHONPATH=src python -m benchmarks.run --op attn [--scale 0.002]
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import dispatch as sparse_dispatch
from repro.core.format import block_format, from_coo
from repro.kernels.ops import attention_hbm_bytes

from .common import attach_bench_json, dtype_bytes, suite, time_fn, write_csv

IMPL_FUSED = "pallas_fused_attn"
IMPL_STAGED = "pallas_staged"
HEADS = (1, 4)
D_HEAD = 32
# precision levels recorded per (matrix, H): dtype tag → precision kwarg
# (attention has no int8 level — per-K-block scales apply to SpMM values)
DTYPE_LEVELS = (("float32", None), ("bfloat16", "bf16"))


def _bench_matrix(g, heads) -> list:
    rng = np.random.default_rng(0)
    fmt = from_coo(g.rows, g.cols, g.vals, (g.num_nodes, g.num_nodes),
                   vector_size=8)
    blocked = block_format(fmt, 8)
    m = g.num_nodes
    recs = []
    for h in heads:
        q = jnp.asarray(rng.standard_normal((h, m, D_HEAD)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((h, m, D_HEAD)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((h, m, D_HEAD)).astype(np.float32))
        for dt, prec in DTYPE_LEVELS:
            for impl, model_impl in ((IMPL_FUSED, "fused"),
                                     (IMPL_STAGED, "staged")):
                fn = lambda: sparse_dispatch.dispatch(
                    "attention", impl, blocked, q, k, v, interpret=True,
                    precision=prec)
                ms = time_fn(fn, reps=3, warmup=1)
                hbm = attention_hbm_bytes(blocked, D_HEAD, D_HEAD, h=h,
                                          impl=model_impl,
                                          value_bytes=dtype_bytes(dt))
                recs.append({
                    "op": "attn",
                    "impl": impl,
                    "matrix": g.name,
                    "h": h,
                    # h is part of the shape key so fused/staged records
                    # pair up per head count in the BENCH summary
                    "shape": [m, m, D_HEAD, h],
                    "nnz": int(g.num_edges),
                    "dtype": dt,
                    "median_ms": round(ms, 3),
                    "hbm_bytes": int(hbm),
                })
                print(f"  {g.name:16s} H={h} {impl:18s} {dt:8s} "
                      f"{ms:8.2f} ms | {hbm / 1e6:8.2f} MB modeled")
    return recs


def run(scale: float = 0.02, heads=HEADS):
    # interpret-mode Pallas executes the kernel bodies in Python: keep the
    # matrix subset small (same reasoning as the fig15 ablation).
    graphs = suite(scale=min(scale, 0.005))[:3]
    recs = []
    for g in graphs:
        recs.extend(_bench_matrix(g, heads))

    fused = {tuple(r["shape"]) + (r["matrix"], r["dtype"]): r["hbm_bytes"]
             for r in recs if r["impl"] == IMPL_FUSED}
    violations = [r for r in recs if r["impl"] == IMPL_STAGED
                  and r["hbm_bytes"] <= fused[tuple(r["shape"])
                                              + (r["matrix"], r["dtype"])]]
    result = {}
    if violations:
        print(f"  WARNING: fused HBM not below staged on "
              f"{len(violations)} shapes")
    attach_bench_json(
        result, recs, "BENCH_attn.json", op="attn",
        fused_impl=IMPL_FUSED, baseline_impl=IMPL_STAGED,
        extra_summary={
            "hbm_strictly_below_staged_everywhere": not violations})
    write_csv("attn.csv", recs)
    return {**result, "rows": recs}
