"""LM pretraining with checkpoint/restart — the fault-tolerance demo.

Trains a reduced config for N steps with async checkpointing, then
SIMULATES A NODE FAILURE by dropping all state, and resumes from the
newest complete checkpoint.  Asserts the resumed run continues seamlessly
(loss keeps decreasing, step counter matches, data pipeline regenerates
the exact batch stream — no iterator hand-off needed).

  PYTHONPATH=src python examples/lm_pretrain.py [--arch qwen3-0.6b]
"""

import argparse
import os
import shutil
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data.synthetic import SyntheticLMData
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import (
    TrainStepConfig, init_train_state, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--fail-at", type=int, default=35)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    ts = TrainStepConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=10,
                                         decay_steps=args.steps))
    data = SyntheticLMData(cfg, args.batch, args.seq, seed=0)
    step_fn = jax.jit(make_train_step(cfg, ts), donate_argnums=0)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    mgr = CheckpointManager(ckpt_dir, keep_n=2)

    def run(state, start, stop, tag):
        losses = []
        for step in range(start, stop):
            batch = jax.tree.map(jnp.asarray, data.batch(step))
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % args.ckpt_every == 0:
                mgr.save_async(state, step + 1)
            if (step + 1) % 10 == 0:
                print(f"  [{tag}] step {step + 1:3d} loss {losses[-1]:.4f}")
        mgr.wait()
        return state, losses

    print(f"phase 1: train to step {args.fail_at}, checkpoints every "
          f"{args.ckpt_every} → {ckpt_dir}")
    state = init_train_state(jax.random.key(0), cfg, ts)
    state, losses1 = run(state, 0, args.fail_at, "run1")

    print("\n>>> simulated node failure: process state dropped <<<\n")
    del state

    latest = mgr.latest_step()
    print(f"phase 2: restart — newest complete checkpoint is step {latest}")
    template = jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), cfg, ts))
    state, resumed_step = mgr.restore(template)
    state = jax.tree.map(jnp.asarray, state)
    assert resumed_step == latest
    assert int(state["step"]) == latest, (int(state["step"]), latest)

    state, losses2 = run(state, resumed_step, args.steps, "run2")

    early = np.mean(losses1[:5])
    late = np.mean(losses2[-5:])
    print(f"\nloss {early:.4f} (start) → {late:.4f} (end), "
          f"resume step {resumed_step}, final step {int(state['step'])}")
    assert late < early, "loss did not decrease across the restart"
    assert int(state["step"]) == args.steps
    print("fault-tolerance demo: PASS (checkpoint → crash → resume → "
          "loss continuity)")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
