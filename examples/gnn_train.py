"""End-to-end GNN training on FlashSparse operators (paper §4.4).

Trains GCN (SpMM aggregation) and AGNN (SDDMM attention + sparse softmax +
SpMM) on a scaled paper graph, comparing the 8×1 and 16×1 pipelines and
f32 vs bf16 numerics — the offline counterpart of paper Fig. 16 / Table 8.

  PYTHONPATH=src python examples/gnn_train.py [--graph GitHub] [--epochs 60]
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import block_format, from_coo
from repro.models.gnn import GNNConfig, init_agnn, init_gcn, make_train_step
from repro.sparse.graphs import make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="GitHub")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--epochs", type=int, default=60)
    ap.add_argument("--model", default="both", choices=["gcn", "agnn", "both"])
    args = ap.parse_args()

    g = make_dataset(args.graph, scale=args.scale)
    print(f"{args.graph} (scale {args.scale}): {g.num_nodes:,} nodes, "
          f"{g.num_edges:,} edges")

    rng = np.random.default_rng(0)
    num_classes, in_dim = 8, 64
    labels_np = rng.integers(0, num_classes, size=g.num_nodes)
    centers = rng.standard_normal((num_classes, in_dim)).astype(np.float32)
    x_np = centers[labels_np] + 0.5 * rng.standard_normal(
        (g.num_nodes, in_dim)).astype(np.float32)
    train_mask = jnp.asarray((rng.random(g.num_nodes) < 0.7), jnp.float32)
    labels = jnp.asarray(labels_np.astype(np.int32))

    models = ["gcn", "agnn"] if args.model == "both" else [args.model]
    for model in models:
        for v, dtype_name in [(8, "f32"), (16, "f32"), (8, "bf16")]:
            dtype = jnp.float32 if dtype_name == "f32" else jnp.bfloat16
            cfg = GNNConfig(model=model, in_dim=in_dim,
                            hidden_dim=128 if model == "gcn" else 32,
                            num_classes=num_classes,
                            num_layers=3 if model == "gcn" else 2,
                            dtype=dtype)
            adj = block_format(from_coo(
                g.rows, g.cols, g.vals, (g.num_nodes, g.num_nodes),
                vector_size=v, dtype=dtype), 8)
            x = jnp.asarray(x_np, dtype)
            init = init_gcn if model == "gcn" else init_agnn
            params = init(jax.random.key(0), cfg)
            mom = jax.tree.map(jnp.zeros_like, params)
            step = make_train_step(cfg, lr=5e-3)

            t0 = time.time()
            for ep in range(args.epochs):
                params, mom, loss, acc = step(params, mom, adj, x, labels,
                                              train_mask)
            jax.block_until_ready(loss)
            dt = (time.time() - t0) / args.epochs * 1e3
            print(f"  {model:4s} V={v:2d} {dtype_name:4s}: "
                  f"{dt:7.1f} ms/epoch | loss {float(loss):.4f} | "
                  f"train acc {float(acc):.3f}")


if __name__ == "__main__":
    main()
