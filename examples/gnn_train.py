"""End-to-end GNN training on FlashSparse operators (paper §4.4).

Trains GCN (SpMM aggregation) and AGNN (SDDMM attention + sparse softmax +
SpMM) on a scaled paper graph, comparing the 8×1 and 16×1 pipelines and
f32 vs bf16 numerics — the offline counterpart of paper Fig. 16 / Table 8.

The adjacency is wrapped in an autodiff plan (``ad_plan``), so ``--impl``
selects any differentiable registry implementation — ``blocked`` (XLA),
``pallas`` or ``pallas_tuned`` — and the backward pass runs the dispatched
transpose-SpMM/SDDMM duality (DESIGN.md §9) through the same kernels.

  PYTHONPATH=src python examples/gnn_train.py [--graph GitHub] [--epochs 60]
  PYTHONPATH=src python examples/gnn_train.py --steps 2 --impl pallas_tuned
      # CI smoke: one small config, asserts finite decreasing loss
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python examples/gnn_train.py --steps 2 --impl pallas_sharded --mesh 4,2
      # multi-device: row segments over the 4-way "data" axis, feature
      # columns over the 2-way "model" axis (DESIGN.md §12)
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import from_coo
from repro.core.autodiff import ad_plan
from repro.models.gnn import GNNConfig, init_agnn, init_gcn, make_train_step
from repro.sparse.graphs import make_dataset


def make_task(g, seed=0, num_classes=8, in_dim=64):
    rng = np.random.default_rng(seed)
    labels_np = rng.integers(0, num_classes, size=g.num_nodes)
    centers = rng.standard_normal((num_classes, in_dim)).astype(np.float32)
    x_np = centers[labels_np] + 0.5 * rng.standard_normal(
        (g.num_nodes, in_dim)).astype(np.float32)
    train_mask = jnp.asarray((rng.random(g.num_nodes) < 0.7), jnp.float32)
    labels = jnp.asarray(labels_np.astype(np.int32))
    return x_np, labels, train_mask


def train_one(g, x_np, labels, train_mask, *, model, v, dtype, impl,
              epochs, num_classes=8, in_dim=64, lr=5e-3, mesh=None):
    cfg = GNNConfig(model=model, in_dim=in_dim,
                    hidden_dim=128 if model == "gcn" else 32,
                    num_classes=num_classes,
                    num_layers=3 if model == "gcn" else 2,
                    impl=impl, dtype=dtype)
    fmt = from_coo(g.rows, g.cols, g.vals, (g.num_nodes, g.num_nodes),
                   vector_size=v, dtype=dtype)
    adj = ad_plan(fmt, impl=impl, n_example=cfg.hidden_dim, mesh=mesh)
    x = jnp.asarray(x_np, dtype)
    init = init_gcn if model == "gcn" else init_agnn
    params = init(jax.random.key(0), cfg)
    mom = jax.tree.map(jnp.zeros_like, params)
    step = make_train_step(cfg, lr=lr)

    losses = []
    t0 = time.time()
    for _ in range(epochs):
        params, mom, loss, acc = step(params, mom, adj, x, labels, train_mask)
        losses.append(loss)  # device arrays: keep the loop async-dispatched
    jax.block_until_ready(loss)
    dt = (time.time() - t0) / max(epochs, 1) * 1e3
    return [float(l) for l in losses], float(acc), dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="GitHub")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--epochs", type=int, default=60)
    ap.add_argument("--model", default="both", choices=["gcn", "agnn", "both"])
    ap.add_argument("--impl", default="blocked",
                    help="registry impl: blocked | pallas | pallas_balanced "
                         "| pallas_tuned | pallas_sharded")
    ap.add_argument("--steps", type=int, default=None,
                    help="smoke mode: run STEPS steps of one small config "
                         "and assert a finite loss decrease (CI gate)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="device grid for --impl pallas_sharded, e.g. 4,2 "
                         "(row segments over 'data', heads/columns over "
                         "'model'); on CPU force host devices first: "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    ap.add_argument("--dtype", default="f32", choices=["f32", "bf16"],
                    help="activation/weight dtype of the smoke config "
                         "(--steps): bf16 runs the mixed-precision kernel "
                         "path end to end, fp32 masters in the optimizer "
                         "(DESIGN.md §13)")
    args = ap.parse_args()

    mesh = None
    if args.mesh is not None:
        from repro.launch.mesh import mesh_from_arg

        mesh = mesh_from_arg(args.mesh)

    if args.steps is not None:
        # CI smoke: tiny graph, one (model, V=8) config, hard asserts.
        scale = min(args.scale, 0.002)
        model = args.model if args.model != "both" else "gcn"
        dtype = jnp.float32 if args.dtype == "f32" else jnp.bfloat16
        g = make_dataset(args.graph, scale=scale)
        x_np, labels, train_mask = make_task(g)
        losses, acc, dt = train_one(
            g, x_np, labels, train_mask, model=model, v=8,
            dtype=dtype, impl=args.impl, epochs=args.steps, lr=5e-2,
            mesh=mesh)
        print(f"smoke {model} impl={args.impl} dtype={args.dtype}: "
              f"loss {losses[0]:.4f} -> "
              f"{losses[-1]:.4f} ({dt:.1f} ms/step)")
        assert all(np.isfinite(l) for l in losses), f"non-finite loss: {losses}"
        assert losses[-1] < losses[0], \
            f"loss did not decrease under impl={args.impl}: {losses}"
        print("OK: finite decreasing loss through the "
              f"{args.impl} gradient path")
        return

    g = make_dataset(args.graph, scale=args.scale)
    print(f"{args.graph} (scale {args.scale}): {g.num_nodes:,} nodes, "
          f"{g.num_edges:,} edges")
    x_np, labels, train_mask = make_task(g)

    models = ["gcn", "agnn"] if args.model == "both" else [args.model]
    for model in models:
        for v, dtype_name in [(8, "f32"), (16, "f32"), (8, "bf16")]:
            dtype = jnp.float32 if dtype_name == "f32" else jnp.bfloat16
            losses, acc, dt = train_one(
                g, x_np, labels, train_mask, model=model, v=v, dtype=dtype,
                impl=args.impl, epochs=args.epochs, mesh=mesh)
            print(f"  {model:4s} V={v:2d} {dtype_name:4s} impl={args.impl}: "
                  f"{dt:7.1f} ms/epoch | loss {losses[-1]:.4f} | "
                  f"train acc {acc:.3f}")


if __name__ == "__main__":
    main()
