"""Beyond-paper integration: FlashSparse block-sparse attention in an LM.

The paper's operators are GNN-flavoured; this example shows the same
SDDMM → sparse-softmax → SpMM pipeline serving as *sparse attention* in a
transformer: a fixed block-sparse causal pattern (local window + strided
global, BigBird-ish) is stored as ME-BCRS at V=8 granularity; attention
scores are computed only at the nonzero pattern (SDDMM), row-normalized
(sparse softmax), and aggregated (SpMM).

The layer lives in ``repro.models.layers.sparse_attention`` and runs
per-head batched on an autodiff plan, so ``--impl pallas``/``pallas_tuned``
executes the fused kernels and ``jax.grad`` flows through the
transpose-SpMM/SDDMM backward duality (DESIGN.md §9) — validated here
against dense masked attention, values *and* gradients.

  PYTHONPATH=src python examples/sparse_attention_lm.py [--impl pallas]
"""

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import from_coo
from repro.core.autodiff import ad_plan
from repro.models.layers import sparse_attention


def block_sparse_causal_pattern(seq: int, window: int = 64, stride: int = 128):
    """Local causal window + strided global tokens (BigBird-ish)."""
    rows, cols = [], []
    for i in range(seq):
        lo = max(0, i - window + 1)
        for j in range(lo, i + 1):
            rows.append(i), cols.append(j)
        for j in range(0, lo, stride):
            rows.append(i), cols.append(j)
    return np.asarray(rows), np.asarray(cols)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--impl", default="blocked",
                    help="registry impl: blocked | pallas | pallas_tuned")
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--heads", type=int, default=2)
    args = ap.parse_args()

    seq, d, heads = args.seq, 64, args.heads
    rows, cols = block_sparse_causal_pattern(seq)
    vals = np.ones_like(rows, np.float32)
    fmt = from_coo(rows, cols, vals, (seq, seq), vector_size=8)
    plan = ad_plan(fmt, impl=args.impl, n_example=d)
    density = len(rows) / seq ** 2
    print(f"pattern: {len(rows):,} nonzeros of {seq * seq:,} "
          f"({density:.1%} dense) — compute saved vs full: {1 - density:.1%}")

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((heads, seq, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((heads, seq, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((heads, seq, d)).astype(np.float32))

    out_sparse = sparse_attention(plan, q, k, v, impl=args.impl)

    # dense oracle: same mask through standard attention, per head
    mask = np.zeros((seq, seq), bool)
    mask[rows, cols] = True

    def dense_head(qh, kh, vh):
        scores = (qh @ kh.T) / np.sqrt(d)
        scores = jnp.where(jnp.asarray(mask), scores, -1e30)
        return jax.nn.softmax(scores, axis=-1) @ vh

    out_dense = jnp.stack([dense_head(q[h], k[h], v[h])
                           for h in range(heads)])

    err = float(jnp.max(jnp.abs(out_sparse - out_dense)))
    print(f"max |sparse - dense masked| = {err:.2e}")
    np.testing.assert_allclose(np.asarray(out_sparse), np.asarray(out_dense),
                               rtol=2e-4, atol=2e-4)
    print("block-sparse attention == dense masked attention  ✓")

    # gradient check: the layer trains (backward = dispatched sparse ops)
    gq = jax.grad(lambda qq: sparse_attention(plan, qq, k, v,
                                              impl=args.impl).sum())(q)
    gq_dense = jax.grad(lambda qq: jnp.stack(
        [dense_head(qq[h], k[h], v[h]) for h in range(heads)]).sum())(q)
    gerr = float(jnp.max(jnp.abs(gq - gq_dense)))
    print(f"max |∂sparse/∂Q - ∂dense/∂Q| = {gerr:.2e}")
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gq_dense),
                               rtol=2e-3, atol=2e-3)
    print("sparse-attention gradients == dense masked gradients  ✓")


if __name__ == "__main__":
    main()
