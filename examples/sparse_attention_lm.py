"""Beyond-paper integration: FlashSparse block-sparse attention in an LM.

The paper's operators are GNN-flavoured; this example shows the same
SDDMM → sparse-softmax → SpMM pipeline serving as *sparse attention* in a
transformer: a fixed block-sparse causal pattern (local window + strided
global, BigBird-ish) is stored as ME-BCRS at V=8 granularity; attention
scores are computed only at the nonzero pattern, row-normalized, and
aggregated.

The layer lives in ``repro.models.layers.sparse_attention``.  With
``--impl pallas``/``pallas_tuned`` it executes the **single-pass fused
megakernel** (DESIGN.md §10): one ``(H, W)`` grid launch computes SDDMM
scores into VMEM, applies the row-segment online softmax, and accumulates
against V — the scores never exist in HBM — and ``jax.grad`` flows through
the FlashAttention-style recompute backward onto the batched transpose-
SpMM/SDDMM duality kernels.  Validated here against dense masked
attention, values *and* gradients, plus (``--steps N``) a tiny training
loop that recovers a value projection through the fused gradient path.

  PYTHONPATH=src python examples/sparse_attention_lm.py \
      [--impl pallas] [--steps 1]
"""

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import dispatch as sparse_dispatch
from repro.core import from_coo
from repro.core.autodiff import ad_plan
from repro.models.layers import sparse_attention


def block_sparse_causal_pattern(seq: int, window: int = 64, stride: int = 128):
    """Local causal window + strided global tokens (BigBird-ish)."""
    rows, cols = [], []
    for i in range(seq):
        lo = max(0, i - window + 1)
        for j in range(lo, i + 1):
            rows.append(i), cols.append(j)
        for j in range(0, lo, stride):
            rows.append(i), cols.append(j)
    return np.asarray(rows), np.asarray(cols)


def train_value_projection(plan, q, k, v, impl: str, steps: int,
                           lr: float = 0.05):
    """Recover a value projection W from attention outputs by SGD — every
    step's forward is the fused megakernel (for Pallas impls) and its
    backward the dispatched sparse duality kernels."""
    d = v.shape[-1]
    target = sparse_attention(plan, q, k, v, impl=impl)
    w = jnp.asarray(np.random.default_rng(1).standard_normal((d, d))
                    .astype(np.float32)) * 0.1

    def loss_fn(w_):
        out = sparse_attention(plan, q, k, v @ w_, impl=impl)
        return jnp.mean((out - target) ** 2)

    loss_grad = jax.jit(jax.value_and_grad(loss_fn))
    with sparse_dispatch.record_calls() as log:
        loss0, _ = loss_grad(w)
    if impl in ("pallas", "pallas_balanced", "pallas_tuned",
                "pallas_sharded"):
        n_fused = (log.count(("attention", "pallas_fused_attn"))
                   + log.count(("attention", "pallas_balanced"))
                   + log.count(("attention", "pallas_sharded")))
        assert n_fused >= 1, f"train step did not hit the fused kernel: {log}"
        n_bwd = sum(1 for op, i in log
                    if op in ("spmm", "sddmm")
                    and i in ("pallas_batched", "pallas_balanced",
                              "pallas_sharded"))
        print(f"train step traced {n_fused} fused-megakernel forward and "
              f"{n_bwd} batched duality-kernel backward dispatches")
    losses = [float(loss0)]
    for _ in range(steps):
        loss, gw = loss_grad(w)
        w = w - lr * gw
        losses.append(float(loss))
    final = float(loss_fn(w))
    assert np.isfinite(losses).all() and np.isfinite(final), losses
    assert final < losses[0], (losses, final)
    print(f"train: loss {losses[0]:.5f} -> {final:.5f} over {steps} "
          f"step(s) through impl={impl}  ✓")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--impl", default="blocked",
                    help="registry impl: blocked | pallas | "
                         "pallas_balanced | pallas_tuned | pallas_sharded")
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--heads", type=int, default=2)
    ap.add_argument("--steps", type=int, default=0,
                    help="run N training steps through the fused gradient "
                         "path after the parity checks")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="device grid for --impl pallas_sharded, e.g. 4,2 "
                         "(sequence windows over 'data', heads over "
                         "'model'); force host devices on CPU via "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    args = ap.parse_args()

    mesh = None
    if args.mesh is not None:
        from repro.launch.mesh import mesh_from_arg

        mesh = mesh_from_arg(args.mesh)

    seq, d, heads = args.seq, 64, args.heads
    rows, cols = block_sparse_causal_pattern(seq)
    vals = np.ones_like(rows, np.float32)
    fmt = from_coo(rows, cols, vals, (seq, seq), vector_size=8)
    plan = ad_plan(fmt, impl=args.impl, n_example=d, mesh=mesh)
    density = len(rows) / seq ** 2
    print(f"pattern: {len(rows):,} nonzeros of {seq * seq:,} "
          f"({density:.1%} dense) — compute saved vs full: {1 - density:.1%}")

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((heads, seq, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((heads, seq, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((heads, seq, d)).astype(np.float32))

    with sparse_dispatch.record_calls() as log:
        out_sparse = sparse_attention(plan, q, k, v, impl=args.impl)
    if args.impl in ("pallas", "pallas_balanced", "pallas_tuned",
                     "pallas_sharded"):
        # a tuned/balanced/sharded plan may route onto the block-parallel
        # or multi-device megakernel
        assert len(log) == 1 and log[0][0] == "attention" and \
            log[0][1] in ("pallas_fused_attn", "pallas_balanced",
                          "pallas_sharded"), log
        print(f"forward: ONE fused megakernel launch for {heads} heads  ✓")

    # dense oracle: same mask through standard attention, per head
    mask = np.zeros((seq, seq), bool)
    mask[rows, cols] = True

    def dense_head(qh, kh, vh):
        scores = (qh @ kh.T) / np.sqrt(d)
        scores = jnp.where(jnp.asarray(mask), scores, -1e30)
        return jax.nn.softmax(scores, axis=-1) @ vh

    out_dense = jnp.stack([dense_head(q[h], k[h], v[h])
                           for h in range(heads)])

    err = float(jnp.max(jnp.abs(out_sparse - out_dense)))
    print(f"max |sparse - dense masked| = {err:.2e}")
    np.testing.assert_allclose(np.asarray(out_sparse), np.asarray(out_dense),
                               rtol=2e-4, atol=2e-4)
    print("block-sparse attention == dense masked attention  ✓")

    # gradient check: the layer trains (backward = dispatched sparse ops)
    gq = jax.grad(lambda qq: sparse_attention(plan, qq, k, v,
                                              impl=args.impl).sum())(q)
    gq_dense = jax.grad(lambda qq: jnp.stack(
        [dense_head(qq[h], k[h], v[h]) for h in range(heads)]).sum())(q)
    gerr = float(jnp.max(jnp.abs(gq - gq_dense)))
    print(f"max |∂sparse/∂Q - ∂dense/∂Q| = {gerr:.2e}")
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gq_dense),
                               rtol=2e-3, atol=2e-3)
    print("sparse-attention gradients == dense masked gradients  ✓")

    if args.steps:
        train_value_projection(plan, q, k, v, args.impl, args.steps)


if __name__ == "__main__":
    main()
