"""Beyond-paper integration: FlashSparse block-sparse attention in an LM.

The paper's operators are GNN-flavoured; this example shows the same
SDDMM → sparse-softmax → SpMM pipeline serving as *sparse attention* in a
transformer: a fixed block-sparse causal pattern (local window + strided
global, BigBird-ish) is stored as ME-BCRS at V=8 granularity; attention
scores are computed only at the nonzero pattern (SDDMM), row-normalized
(sparse softmax), and aggregated (SpMM).

Validates against dense masked attention, and reports the compute saved
vs dense full attention.

  PYTHONPATH=src python examples/sparse_attention_lm.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import block_format, from_coo, sddmm_blocked, spmm_blocked, with_values
from repro.core.softmax import sparse_softmax


def block_sparse_causal_pattern(seq: int, window: int = 64, stride: int = 128):
    """Local causal window + strided global tokens (BigBird-ish)."""
    rows, cols = [], []
    for i in range(seq):
        lo = max(0, i - window + 1)
        for j in range(lo, i + 1):
            rows.append(i), cols.append(j)
        for j in range(0, lo, stride):
            rows.append(i), cols.append(j)
    return np.asarray(rows), np.asarray(cols)


def sparse_attention(blocked, q, k, v):
    """One head of FlashSparse attention: SDDMM → softmax → SpMM."""
    scores = sddmm_blocked(blocked, q, k) / np.sqrt(q.shape[-1])
    probs = sparse_softmax(blocked, scores)
    return spmm_blocked(with_values(blocked, probs.astype(v.dtype)), v)


def main():
    seq, d = 512, 64
    rows, cols = block_sparse_causal_pattern(seq)
    vals = np.ones_like(rows, np.float32)
    fmt = from_coo(rows, cols, vals, (seq, seq), vector_size=8)
    blocked = block_format(fmt, k_blk=8)
    density = len(rows) / seq ** 2
    print(f"pattern: {len(rows):,} nonzeros of {seq * seq:,} "
          f"({density:.1%} dense) — compute saved vs full: {1 - density:.1%}")

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((seq, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((seq, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((seq, d)).astype(np.float32))

    out_sparse = sparse_attention(blocked, q, k, v)

    # dense oracle: same mask through standard attention
    mask = np.zeros((seq, seq), bool)
    mask[rows, cols] = True
    scores = (q @ k.T) / np.sqrt(d)
    scores = jnp.where(jnp.asarray(mask), scores, -1e30)
    out_dense = jax.nn.softmax(scores, axis=-1) @ v

    err = float(jnp.max(jnp.abs(out_sparse - out_dense)))
    print(f"max |sparse - dense masked| = {err:.2e}")
    np.testing.assert_allclose(np.asarray(out_sparse), np.asarray(out_dense),
                               rtol=2e-4, atol=2e-4)
    print("block-sparse attention == dense masked attention  ✓")


if __name__ == "__main__":
    main()
