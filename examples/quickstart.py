"""Quickstart: the FlashSparse public API in 60 lines.

  PYTHONPATH=src python examples/quickstart.py

Covers: building ME-BCRS from COO, SpMM/SDDMM through the XLA and Pallas
paths, the sparse-softmax composition (SDDMM → softmax → SpMM, the AGNN
attention pattern), and the redundancy metrics that motivate the paper.
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    block_format, from_coo, mma_count, sddmm, spmm, summarize, to_dense,
    with_values, zeros_in_nonzero_vectors,
)
from repro.core.softmax import sparse_softmax
from repro.sparse.graphs import make_dataset

# 1. a scaled replica of the paper's GitHub graph ---------------------------
g = make_dataset("GitHub", scale=0.02)
shape = (g.num_nodes, g.num_nodes)
print(f"graph: {g.num_nodes:,} nodes, {g.num_edges:,} edges")

# 2. translate to ME-BCRS at the paper's two granularities ------------------
f8 = from_coo(g.rows, g.cols, g.vals, shape, vector_size=8)
f16 = from_coo(g.rows, g.cols, g.vals, shape, vector_size=16)
print(f"8x1  vectors: {f8.nnzv:,}  carried zeros: {zeros_in_nonzero_vectors(f8):,}")
print(f"16x1 vectors: {f16.nnzv:,}  carried zeros: {zeros_in_nonzero_vectors(f16):,}")
print(f"MMA invocations (N=16): 16x1 = {mma_count(f16, 16):,} "
      f"vs 8x1 = {mma_count(f8, 16):,}")

# 3. SpMM: sparse adjacency @ dense features --------------------------------
feats = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((g.num_nodes, 64)).astype(np.float32))
out_xla = spmm(f8, feats, impl="blocked")          # XLA path
blocked = block_format(f8, k_blk=8)
from repro.kernels import ops
out_pallas = ops.spmm(blocked, feats)              # Pallas kernel (interpret)
np.testing.assert_allclose(np.asarray(out_xla), np.asarray(out_pallas),
                           rtol=1e-4, atol=1e-4)
print("SpMM: XLA blocked path == Pallas kernel  ✓")

# 4. SDDMM → sparse softmax → SpMM (the AGNN attention pattern) -------------
scores = sddmm(f8, feats, feats)                   # sampled QK^T at A's pattern
probs = sparse_softmax(blocked, scores)            # row softmax, blocked layout
attended = spmm(with_values(blocked, probs), feats)
print(f"AGNN attention pipeline: out {attended.shape}, "
      f"finite: {bool(jnp.all(jnp.isfinite(attended)))}")

# 5. the paper's redundancy story in one dict -------------------------------
print("\nredundancy summary (8x1):")
for k, v in summarize(f8, 128).items():
    print(f"  {k:18s} {v:,.0f}" if isinstance(v, (int, float)) else f"  {k}: {v}")
