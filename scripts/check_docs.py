"""Docs CI gate: run every documented code snippet; verify the impl matrix.

  PYTHONPATH=src python scripts/check_docs.py

Two checks, both designed so the docs cannot silently rot:

1. **Snippets run.** Every fenced ```python block in README.md and
   docs/*.md is executed in order within one namespace per file (later
   blocks may use names defined by earlier ones, like a reader following
   the page top to bottom).  Blocks fenced as ```text / ```bash / ```json
   are illustrative and skipped.
2. **The impl matrix is current.** The README's implementation table is
   regenerated from the dispatch registry (scripts/impl_matrix.py) and
   compared verbatim; registering a new impl without updating README
   fails CI.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def run_snippets(path: pathlib.Path) -> int:
    """Execute the file's ```python blocks in one shared namespace."""
    blocks = _FENCE.findall(path.read_text())
    ns: dict = {"__name__": f"docs_snippet_{path.stem}"}
    for i, code in enumerate(blocks):
        print(f"  {path.relative_to(ROOT)} block {i + 1}/{len(blocks)} "
              f"({len(code.splitlines())} lines)")
        try:
            exec(compile(code, f"{path.name}[block {i + 1}]", "exec"), ns)
        except Exception:
            print(f"FAILED: snippet {i + 1} of {path}", file=sys.stderr)
            raise
    return len(blocks)


def check_matrix() -> None:
    sys.path.insert(0, str(ROOT / "scripts"))
    from impl_matrix import impl_matrix

    want = impl_matrix().strip()
    readme = (ROOT / "README.md").read_text()
    if want not in readme:
        print("README impl matrix is stale — regenerate with:\n"
              "  PYTHONPATH=src python scripts/impl_matrix.py",
              file=sys.stderr)
        print("\nexpected:\n" + want, file=sys.stderr)
        raise SystemExit(1)
    print("  README impl matrix matches the dispatch registry")


def main() -> int:
    total = 0
    for path in DOCS:
        if path.exists():
            total += run_snippets(path)
    check_matrix()
    print(f"OK: {total} python snippets ran, impl matrix current")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
