#!/usr/bin/env bash
# Tier-1 verification: the gate every PR must keep green.
#
#   scripts/tier1.sh            # full suite + gradient-path smoke
#   scripts/tier1.sh tests/test_kernels.py   # pass-through pytest args
#
# Installs dev deps (hypothesis) when a network is available; offline, the
# property tests degrade to skips via tests/_hypothesis_compat.py.
# TIER1_SMOKE=0 skips the gnn_train gradient smoke (pytest-only runs).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if ! python -c "import hypothesis" >/dev/null 2>&1; then
  pip install -r requirements-dev.txt \
    || echo "warn: dev deps unavailable (offline?); property tests will skip"
fi

python -m pytest -x -q "$@"

# Hardened-runtime gate (DESIGN.md §15, full runs only): re-run the
# format/op/dispatch tests with ambient full validation on every
# constructor and dispatch (REPRO_CHECK=full must be behavior-preserving
# on healthy inputs), then smoke the fault-injection harness CLI in both
# strictness modes.
if [[ $# -eq 0 ]]; then
  REPRO_CHECK=full python -m pytest -x -q \
    tests/test_format.py tests/test_sparse_ops.py tests/test_dispatch.py \
    tests/test_validate.py
  python -m repro.testing.faults --op spmm --impl blocked --strict
  python -m repro.testing.faults --op spmm --impl pallas --interpret \
    --no-strict

  # Real-matrix conformance gate: the harness must catch a broken impl
  # (self-test), then the full registry — every (op, impl, precision) —
  # must match the dense oracle on a two-matrix vendored subset.  The
  # full 14-matrix sweep is the real-matrix-conformance CI job.
  python -m repro.testing.conformance --self-test
  python -m repro.testing.conformance \
    --datasets densearray_8x6,mesh3d_4 --precision fp32
fi

# Gradient-path smoke (full runs only): two training steps through the
# autotuned Pallas impl must produce a finite, decreasing loss — the
# backward runs the transpose-SpMM/SDDMM duality (DESIGN.md §9).
if [[ $# -eq 0 && "${TIER1_SMOKE:-1}" == "1" ]]; then
  python examples/gnn_train.py --steps 2 --impl pallas_tuned \
    --model gcn --scale 0.002

  # Fused-attention smoke (interpret mode): LM example forward + one
  # train step through the single-pass pallas_fused_attn megakernel —
  # dense-oracle parity for values and gradients, one launch for all
  # heads, decreasing loss (DESIGN.md §10).
  python examples/sparse_attention_lm.py --impl pallas --seq 256 --steps 1

  # Block-parallel scheduling floor (DESIGN.md §11): skewed hub-row
  # matrices through the balanced-vs-window comparison; the balanced
  # schedule must cut the idle-cell-adjusted cost >= 1.3x on every
  # skew >= 1.5 matrix (bitwise kernel parity is asserted inside the
  # bench itself).  REPRO_CHECK=full doubles as the §15 full-validation
  # pass over the bench suite: every constructor and dispatch in the
  # bench audits its formats/schedules host-side (bench numbers are
  # cost-model floors, not wall-clock, so the audit does not skew them).
  # --datasets folds the vendored real-matrix records into the same run:
  # every dataset record asserts oracle parity before timing, and the
  # summary maps each structure class to its winning impl.
  REPRO_CHECK=full python -m benchmarks.run --op spmm --skewed --datasets \
    --scale 0.002
  python - <<'EOF'
import json
with open("BENCH_spmm.json") as f:
    summary = json.load(f)["summary"]
# Real-matrix floor: every vendored-dataset record passed its dense-
# oracle parity check, and every structure class elected a winner.
assert summary["datasets_parity_ok"], "dataset record failed oracle parity"
winners = summary["class_winners"]
print("per-class winners: " + ", ".join(
    f"{c}->{w['impl']}" for c, w in sorted(winners.items())))
assert winners, "no structure-class winners recorded"
red = summary["balanced_cost_reduction_min"]
print(f"skewed balanced-vs-window cost min {red:.2f}x")
assert red >= 1.3, f"balanced scheduling floor regressed: {red}"
# Device-level partitioner floor (DESIGN.md §12): balance_cost max/mean
# across 8 devices must stay <= 1.25 on every skewed matrix — the
# partitioner balances by the cost model, not just splits evenly.
bal = summary["device_balance_max_over_mean_8dev"]
print(f"8-device partition balance max/mean {bal:.3f}")
assert bal <= 1.25, f"device partition balance regressed: {bal}"
# Mixed-precision floor (DESIGN.md §13): the bf16 fused-SpMM records must
# model >= 1.8x less HBM traffic than fp32 on the standard suite (< 2x
# only because the int32 metadata stream does not narrow).
mp = summary["hbm_reduction_geomean_bf16_vs_fp32"]
print(f"bf16/fp32 modeled HBM reduction geomean {mp:.2f}x")
assert mp >= 1.8, f"mixed-precision HBM floor regressed: {mp}"
# Overlapped-ring floor (DESIGN.md §14): modeled overlapped-vs-bulk
# makespan (best over n_batches) must stay >= 1.15x at 8 devices on
# every row-balanced overlap-suite matrix (currently min ~1.66x).
ovl = summary["overlap_makespan_improvement_min_8dev"]
print(f"8-device overlap/bulk makespan min {ovl:.2f}x")
assert ovl >= 1.15, f"overlapped-ring makespan floor regressed: {ovl}"
EOF

  # Multi-device sharded smoke (DESIGN.md §12): two training steps through
  # impl=pallas_sharded on an 8-way forced-host-device mesh — forward and
  # both duality backward ops run one local balanced launch per device
  # under shard_map, loss must decrease.
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python examples/gnn_train.py --steps 2 --impl pallas_sharded \
    --mesh 4,2 --model gcn --scale 0.002

  # Overlapped sharded smoke (DESIGN.md §14): same mesh, but the trailing
  # psum replaced by the double-buffered ppermute ring over segment
  # batches — forward and both duality backward ops run the overlap path.
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python examples/gnn_train.py --steps 2 --impl pallas_sharded_overlap \
    --mesh 4,2 --model gcn --scale 0.002
fi
