#!/usr/bin/env bash
# Tier-1 verification: the gate every PR must keep green.
#
#   scripts/tier1.sh            # full suite
#   scripts/tier1.sh tests/test_kernels.py   # pass-through pytest args
#
# Installs dev deps (hypothesis) when a network is available; offline, the
# property tests degrade to skips via tests/_hypothesis_compat.py.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if ! python -c "import hypothesis" >/dev/null 2>&1; then
  pip install -r requirements-dev.txt \
    || echo "warn: dev deps unavailable (offline?); property tests will skip"
fi

exec python -m pytest -x -q "$@"
