"""Generate the README implementation matrix from the dispatch registry.

  PYTHONPATH=src python scripts/impl_matrix.py

Prints a GitHub-markdown table of every registered (op, impl) pair with
its capability flags, pulled live from :mod:`repro.core.dispatch` — the
single source of truth every layer resolves implementations through.
``scripts/check_docs.py`` regenerates this table in CI and fails if the
committed README copy has drifted.
"""

from __future__ import annotations

OPS = ("spmm", "sddmm", "attention")
FLAGS = (
    ("differentiable", "grad"),
    ("batched", "batched"),
    ("load_balanced", "balanced"),
    ("multi_device", "multi-dev"),
    ("overlapped", "overlap"),
    ("needs_canonical", "canonical-in"),
    ("returns_format", "format-out"),
)


def _fallback_cell(name: str, entries) -> str:
    """Next ladder rung per op (DESIGN.md §15).  One shared rung renders
    bare; per-op differences render ``op:rung``; terminal/no-fallback
    impls render a dash."""
    from repro.core import dispatch

    fbs = {op: dispatch.fallback_for(op, name) for op in entries}
    uniq = {fb for fb in fbs.values() if fb is not None}
    if not uniq:
        return "—"
    if len(uniq) == 1 and all(fb is not None for fb in fbs.values()):
        return f"`{uniq.pop()}`"
    return " ".join(f"{op}:`{fb}`" if fb else f"{op}:—"
                    for op, fb in fbs.items())


def _precision_cell(entries) -> str:
    """Union of precision levels over the ops an impl serves, in canonical
    order (DESIGN.md §13) — fp32-only renders as a dash (the default)."""
    levels = {p for e in entries.values() for p in e.precisions}
    if levels <= {"fp32"}:
        return "—"
    return "/".join(p for p in ("fp32", "bf16", "int8") if p in levels)


def impl_matrix() -> str:
    """The implementation matrix as a GitHub-markdown table string."""
    from repro.core import dispatch

    names = sorted({n for op in OPS for n in dispatch.impls(op)})
    header = (["impl"] + [f"{op}" for op in OPS]
              + [lbl for _, lbl in FLAGS] + ["precision", "fallback"])
    lines = ["| " + " | ".join(header) + " |",
             "|" + "|".join("---" for _ in header) + "|"]
    for name in names:
        entries = {op: dispatch.get(op, name) for op in OPS
                   if name in dispatch.impls(op)}
        row = [f"`{name}`"]
        row += ["✓" if op in entries else "—" for op in OPS]
        for flag, _ in FLAGS:
            vals = {getattr(e, flag) for e in entries.values()}
            row.append("✓" if vals == {True} else
                       ("—" if vals == {False} else "mixed"))
        row.append(_precision_cell(entries))
        row.append(_fallback_cell(name, entries))
        lines.append("| " + " | ".join(row) + " |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(impl_matrix())
