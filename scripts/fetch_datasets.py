#!/usr/bin/env python
"""Fetch the download-only datasets from tests/data/manifest.json.

The vendored sample set ships in-repo and is all CI ever touches; this
script pulls the *full* catalog (SuiteSparse Matrix Market tarballs) so
local runs of the conformance harness and ``benchmarks/run.py
--datasets`` can cover real full-size matrices:

    python scripts/fetch_datasets.py              # everything missing
    python scripts/fetch_datasets.py bcsstk01     # named entries only
    python scripts/fetch_datasets.py --list       # show catalog + status

Downloads land next to the vendored files (or in $REPRO_DATASETS_DIR)
and are picked up automatically by ``repro.data.load_vendored()``.
Never run in CI — the conformance job must stay offline.
"""

import argparse
import gzip
import io
import pathlib
import sys
import tarfile
import urllib.request

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.data.datasets import load_manifest, vendored_dir  # noqa: E402


def fetch(entry, data_dir: pathlib.Path) -> pathlib.Path:
    rel = entry.get("extract") or f"{entry['name']}.mtx"
    dest = data_dir / rel
    if dest.exists():
        print(f"  {entry['name']}: already present ({dest})")
        return dest
    url = entry["url"]
    print(f"  {entry['name']}: fetching {url}")
    with urllib.request.urlopen(url, timeout=60) as resp:
        raw = resp.read()
    dest.parent.mkdir(parents=True, exist_ok=True)
    if url.endswith((".tar.gz", ".tgz")):
        with tarfile.open(fileobj=io.BytesIO(raw), mode="r:gz") as tar:
            member = tar.getmember(rel)
            src = tar.extractfile(member)
            assert src is not None, f"{rel} is not a regular file in {url}"
            dest.write_bytes(src.read())
    elif url.endswith(".gz"):
        dest.write_bytes(gzip.decompress(raw))
    else:
        dest.write_bytes(raw)
    print(f"  {entry['name']}: wrote {dest}")
    return dest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("names", nargs="*",
                    help="manifest entries to fetch (default: all missing)")
    ap.add_argument("--list", action="store_true",
                    help="print the catalog and local status, fetch nothing")
    args = ap.parse_args(argv)

    data_dir = vendored_dir()
    manifest = load_manifest(data_dir)
    remote = [d for d in manifest["datasets"] if d.get("url")]

    if args.list:
        for d in manifest["datasets"]:
            rel = d.get("file") or d.get("extract") or f"{d['name']}.mtx"
            state = ("vendored" if d.get("file")
                     else "fetched" if (data_dir / rel).exists()
                     else "missing")
            print(f"{d['name']:16s} {d['structure_class']:8s} {state}")
        return 0

    if args.names:
        known = {d["name"]: d for d in remote}
        unknown = [n for n in args.names if n not in known]
        if unknown:
            ap.error(f"not download-only manifest entries: {unknown} "
                     f"(catalog: {sorted(known)})")
        todo = [known[n] for n in args.names]
    else:
        todo = remote

    print(f"fetching into {data_dir}")
    failures = 0
    for entry in todo:
        try:
            fetch(entry, data_dir)
        except Exception as e:  # keep going; report at the end
            failures += 1
            print(f"  {entry['name']}: FAILED ({e})")
    if failures:
        print(f"{failures}/{len(todo)} downloads failed (offline?); "
              "the vendored set still covers every structure class")
    return 1 if failures == len(todo) and todo else 0


if __name__ == "__main__":
    raise SystemExit(main())
